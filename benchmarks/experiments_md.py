"""Assemble the generated tables of EXPERIMENTS.md from artifacts.

    PYTHONPATH=src python -m benchmarks.experiments_md > EXPERIMENTS_TABLES.md

The narrative sections live in EXPERIMENTS.md and reference these tables.
"""
from __future__ import annotations

import glob
import json
import os


def load(art_dir):
    recs = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        r["_file"] = os.path.basename(path)
        recs.append(r)
    return recs


def _gib(x):
    return f"{x/2**30:.2f}"


def dryrun_table(recs, mesh):
    rows = [
        "| arch | shape | step | compile s | args GiB/dev | temp GiB/dev | coll GiB/dev (wire) |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("status") != "ok" or r["mesh"] != mesh:
            continue
        c = r["collectives"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['step']} | {r.get('compile_s', 0):.1f} "
            f"| {_gib(r['memory']['argument_bytes'])} | {_gib(r['memory']['temp_bytes'])} "
            f"| {_gib(c.get('total', 0))} |"
        )
    return "\n".join(rows)


def roofline_table(recs, mesh="single"):
    rows = [
        "| arch | shape | step | FLOPs/dev | HBM B/dev | coll B/dev | compute s | memory s | coll s | dominant | useful | scan-corr |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("status") != "ok" or r["mesh"] != mesh or r["step"] == "train_global":
            continue
        ro = r["roofline"]
        corrected = "yes" if r.get("cost_corrected") else "RAW*"
        useful = f"{ro['useful_ratio']:.3f}" if ro.get("useful_ratio") else "-"
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['step']} "
            f"| {ro['flops_per_device']:.2e} | {ro['hbm_bytes_per_device']:.2e} "
            f"| {ro['collective_bytes_per_device']:.2e} "
            f"| {ro['compute_s']:.2e} | {ro['memory_s']:.2e} | {ro['collective_s']:.2e} "
            f"| **{ro['dominant']}** | {useful} | {corrected} |"
        )
    rows.append(
        "\n*RAW rows: XLA while-body single-counting not yet extrapolated "
        "(undercounts scanned-layer FLOPs/bytes by ~n_layers; useful-ratio "
        "inflated) — run repro/launch/cost_correction.py to correct in place."
    )
    return "\n".join(rows)


def perf_table(recs):
    rows = [
        "| variant | step | FLOPs/dev | HBM B/dev | coll B/dev (wire) | compute s | memory s | coll s | dominant |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("status") != "ok":
            continue
        ro = r["roofline"]
        tag = r["_file"].replace(".json", "").split("__")[-1]
        rows.append(
            f"| {r['arch'].split('-')[0]}/{r['shape']}/{tag} | {r['step']} "
            f"| {ro['flops_per_device']:.2e} | {ro['hbm_bytes_per_device']:.2e} "
            f"| {ro['collective_bytes_per_device']:.2e} "
            f"| {ro['compute_s']:.2e} | {ro['memory_s']:.2e} | {ro['collective_s']:.2e} "
            f"| {ro['dominant']} |"
        )
    return "\n".join(rows)


def main():
    dry = load("artifacts/dryrun")
    perf = load("artifacts/perf")
    print("## Generated tables\n")
    print("### T1 — Dry-run, single pod (16×16 = 256 chips)\n")
    print(dryrun_table(dry, "single"))
    print("\n### T2 — Dry-run, multi-pod (2×16×16 = 512 chips)\n")
    print(dryrun_table(dry, "multi"))
    print("\n### T3 — Roofline, single pod (scan-corrected)\n")
    print(roofline_table(dry, "single"))
    print("\n### T4 — Roofline, multi-pod\n")
    print(roofline_table(dry, "multi"))
    print("\n### T5 — Perf iterations (hillclimb + beyond-paper)\n")
    print(perf_table(perf))


if __name__ == "__main__":
    main()
