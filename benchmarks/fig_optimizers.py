"""Optimizer sweep: pluggable local/server update rules × server probability.

The paper studies plain tracked-SGD only; with the update-rule API
(DESIGN.md §10) the same PISCO substrate runs adaptive local steps and
FedOpt-style server rounds.  This sweep crosses

    local  ∈ {sgd, momentum, adam}      (the tracker is the descent direction)
    server ∈ {none, fedavgm, fedadam}   (fires at global-averaging rounds)
    p      ∈ {0.05, 0.2}                (agent-to-server probability)

on the §5.1 logreg workload and reads out rounds/bytes-to-target plus final
gradient norm, pricing the extra traffic honestly (a server rule ships one
extra payload per direction; mixed momentum buffers ride the gossip links).

Emits ``BENCH_optimizers.json`` under ``artifacts/bench/``.

    PYTHONPATH=src python -m benchmarks.fig_optimizers [--quick]
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import make_logreg_workload, run_pisco_variant, save_result

LOCAL_RULES = [None, "momentum:lr=0.1", "adam:lr=0.05"]
SERVER_RULES = [None, "fedavgm", "fedadam"]
P_GRID = [0.05, 0.2]


def _label(rule):
    return "sgd" if rule is None else rule.split(":")[0]


def _cell_readout(hist, grad_target: float) -> dict:
    acct = hist.accountant
    cum_bytes = np.cumsum(acct.per_round_bytes)
    r = hist.rounds_to_threshold("grad_sq", grad_target, mode="running_le")
    return {
        "rounds_to_target": None if r is None else r + 1,
        "bytes_to_target": None if r is None else int(cum_bytes[r]),
        "total_bytes": int(acct.total_bytes),
        "server_rounds": int(acct.agent_to_server),
        "final_grad_sq": float(hist.grad_sq_norm[-1]),
        "final_loss": float(hist.loss[-1]),
    }


def run(quick: bool = False, seed: int = 0) -> dict:
    rounds = 120 if quick else 500
    locals_ = LOCAL_RULES[:2] if quick else LOCAL_RULES
    servers = SERVER_RULES[:2] if quick else SERVER_RULES
    ps = [0.2] if quick else P_GRID
    grad_target = 0.01 if quick else 0.002

    data, loss_fn, eval_fn, params0 = make_logreg_workload(quick=quick, seed=seed)
    results = {}
    for p in ps:
        for local in locals_:
            for server in servers:
                hist, _ = run_pisco_variant(
                    data=data, loss_fn=loss_fn, eval_fn=eval_fn,
                    params0=params0, p=p, t_o=2, eta_l=0.3, rounds=rounds,
                    seed=seed,
                    optimizer=local, server_optimizer=server,
                )
                key = (
                    f"local={_label(local)},"
                    f"server={server or 'none'},p={p:.2f}"
                )
                results[key] = _cell_readout(hist, grad_target)
    payload = {"bench": "fig_optimizers", "quick": quick, "results": results}
    save_result("BENCH_optimizers", payload)
    return payload


def best_adaptive_speedup(results: dict):
    """Rounds-to-target speedup of the best non-SGD cell over the plain-SGD
    cell at the same p (None if either never reached the target)."""
    speedups = []
    for key, cell in results.items():
        if key.startswith("local=sgd,server=none") or not cell["rounds_to_target"]:
            continue
        p_tag = key.split(",p=")[1]
        base = results.get(f"local=sgd,server=none,p={p_tag}")
        if base and base["rounds_to_target"]:
            speedups.append(base["rounds_to_target"] / cell["rounds_to_target"])
    return max(speedups) if speedups else None


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    payload = run(quick=args.quick)
    print(f"{'scenario':>38} | {'rounds':>7} {'MB@target':>10} {'final |g|^2':>12}")
    for key, cell in payload["results"].items():
        rt = cell["rounds_to_target"]
        bt = cell["bytes_to_target"]
        print(
            f"{key:>38} | "
            f"{rt if rt is not None else '---':>7} "
            f"{bt / 1e6 if bt is not None else float('nan'):10.3f} "
            f"{cell['final_grad_sq']:12.3e}"
        )
    s = best_adaptive_speedup(payload["results"])
    if s:
        print(f"best adaptive rounds-to-target speedup vs plain SGD: {s:.2f}x")


if __name__ == "__main__":
    main()
