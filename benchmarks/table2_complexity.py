"""Table 2 reproduction: expected agent-to-server / agent-to-agent
communication rounds to reach epsilon-accuracy, for every algorithm's
leading-order bound, evaluated at representative problem constants.

This is the analytic comparison the paper tabulates; we evaluate the bounds
(up to the common constant) so the crossovers (network dependency, local-
update speedup, the p-tradeoff of PISCO) are visible numerically.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import save_result


def bounds(n, t_o, lam_w, p, sigma, eps):
    """Leading terms from Table 2 (L = 1, constants dropped)."""
    lam_p = lam_w + p * (1 - lam_w)
    scaffold_server = sigma**2 / (n * t_o * eps**4) + 1 / eps**2
    lsgt_a2a = (
        sigma**4 / (n * t_o * lam_w**8 * eps**4)
        + 1 / (n * t_o ** (1 / 3) * lam_w ** (8 / 3) * eps ** (4 / 3))
        + 1 / (n * t_o * eps**2)
        if lam_w > 0 else np.inf
    )
    periodical_gt_a2a = (
        sigma**2 / (n * t_o * eps**4) + sigma / (lam_w**2 * eps**3) + 1 / (lam_w**2 * eps**2)
        if lam_w > 0 else np.inf
    )
    k_gt_a2a = (
        sigma**2 / (n * t_o * eps**4)
        + sigma / (lam_w**2 * np.sqrt(t_o) * eps**3)
        + 1 / (lam_w**2 * eps**2)
        if lam_w > 0 else np.inf
    )
    pisco_total = (
        sigma**2 / (n * t_o * eps**4) + sigma / (lam_p**2 * eps**3) + 1 / (n * eps**2)
    )
    return {
        "SCAFFOLD (server)": scaffold_server,
        "LSGT (a2a)": lsgt_a2a,
        "Periodical-GT (a2a)": periodical_gt_a2a,
        "K-GT (a2a)": k_gt_a2a,
        "PISCO (server)": p * pisco_total,
        "PISCO (a2a)": (1 - p) * pisco_total,
        "PISCO (total)": pisco_total,
    }


def network_dependency_sweep():
    """Remark 4: p = Theta(sqrt(lam_w)) improves dependency lam_w^-2 -> lam_w^-1."""
    rows = []
    for lam_w in (1e-1, 1e-2, 1e-3, 1e-4):
        for p in (0.0, lam_w, np.sqrt(lam_w), 1.0):
            lam_p = lam_w + p * (1 - lam_w)
            rows.append(
                {
                    "lambda_w": lam_w,
                    "p": float(p),
                    "lambda_p": float(lam_p),
                    "network_term": float(1.0 / lam_p**2),
                }
            )
    return rows


def run(quick: bool = False) -> dict:
    consts = dict(n=10, t_o=10, sigma=1.0, eps=0.05)
    table = {}
    for lam_w, p in ((0.24, 0.1), (0.01, 0.1), (0.01, 0.0), (0.24, 1.0)):
        key = f"lam_w={lam_w},p={p}"
        table[key] = {
            k: (float(v) if np.isfinite(v) else None)
            for k, v in bounds(lam_w=lam_w, p=p, **consts).items()
        }
    payload = {
        "bench": "table2_complexity",
        "constants": consts,
        "table": table,
        "network_dependency": network_dependency_sweep(),
    }
    save_result("table2_complexity", payload)
    return payload


def main():
    payload = run()
    for key, row in payload["table"].items():
        print(f"--- {key}")
        for alg, v in row.items():
            print(f"   {alg:>22}: {v:.3e}" if v is not None else f"   {alg:>22}: inf")
    print("--- network dependency (Remark 4)")
    for r in payload["network_dependency"]:
        print(
            f"   lam_w={r['lambda_w']:.0e} p={r['p']:.3g} -> lam_p={r['lambda_p']:.3g} "
            f"1/lam_p^2={r['network_term']:.3e}"
        )


if __name__ == "__main__":
    main()
