"""Sparse-substrate scaling benchmark (DESIGN.md §12).

Per-round wall time and peak mixing-state memory for the edge-list/CSR
gossip path across fleet sizes n ∈ {64, 1024, 4096, 10^4}, plus the n = 64
dense-vs-sparse parity pin that keeps the sparse path honest.  The workload
is a tiny per-agent quadratic (loss = 0.5·mean((w − target)^2)) so the
numbers isolate the mixing substrate, not the model.

Memory is reported analytically (the simulation is single-host, so resident
set tells you little): the dense path's mixing state is the n×n float32 W;
the sparse path's is the directed edge arrays (2m weights + 2m int32 sender/
receiver indices) plus the (n,) self-weight vector.

    PYTHONPATH=src python -m benchmarks.fig_sparse
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_result
from repro.core import (
    PiscoConfig,
    dense_mixing,
    make_sparse_topology,
    make_topology,
    replicate_params,
    run_training,
    sparse_mixing,
)

FLEET_SIZES = (64, 1024, 4096, 10_000)
PARITY_N = 64


def _workload(n: int, d: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    targets = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))

    def loss_fn(params, batch):
        return 0.5 * jnp.mean((params["w"] - batch) ** 2)

    def sampler(k):
        return jnp.stack([targets, targets]), targets

    x0 = replicate_params({"w": jnp.zeros(d, jnp.float32)}, n)
    return loss_fn, sampler, x0


def _run(n: int, d: int, mixing, rounds: int, seed: int = 0):
    loss_fn, sampler, x0 = _workload(n, d, seed)
    cfg = PiscoConfig(n_agents=n, t_o=2, eta_l=0.1, eta_c=1.0, p=0.1, seed=seed)
    return run_training(
        "pisco", loss_fn, x0, cfg, mixing, sampler,
        rounds=rounds, driver="scan", block_size=rounds,
    )


def _mixing_state_bytes(n: int, m: int, sparse: bool) -> int:
    if sparse:
        # directed edge weights (2m f32) + senders/receivers (2m i32 each)
        # + self weights (n f32)
        return 2 * m * 4 + 2 * (2 * m * 4) + n * 4
    return n * n * 4  # the dense float32 W


def run(quick: bool = True) -> dict:
    d = 8 if quick else 256
    rounds = 4 if quick else 20
    results = {}
    for n in FLEET_SIZES:
        topo = make_sparse_topology("ring", n)
        mixing = sparse_mixing(topo)
        # warm-up run compiles the block; the timed run measures steady state
        _run(n, d, mixing, 1)
        t0 = time.perf_counter()
        hist = _run(n, d, mixing, rounds)
        dt = time.perf_counter() - t0
        m = topo.n_edges
        results[f"n={n}"] = {
            "n_agents": n,
            "n_edges": m,
            "rounds": rounds,
            "per_round_s": dt / rounds,
            "sparse_mixing_state_bytes": _mixing_state_bytes(n, m, True),
            "dense_mixing_state_bytes": _mixing_state_bytes(n, m, False),
            "final_loss": float(hist.loss[-1]),
        }

    # n = 64 parity pin: dense and sparse runs must agree round-for-round
    n = PARITY_N
    hd = _run(n, d, dense_mixing(make_topology("ring", n)), rounds)
    hs = _run(n, d, sparse_mixing(make_sparse_topology("ring", n)), rounds)
    max_dev = float(np.max(np.abs(np.array(hd.loss) - np.array(hs.loss))))
    parity_ok = bool(np.allclose(hd.loss, hs.loss, rtol=1e-5, atol=1e-6))
    assert parity_ok, f"dense/sparse parity broken at n={n}: max dev {max_dev}"

    payload = {
        "results": results,
        "parity": {"n": n, "ok": parity_ok, "max_loss_dev": max_dev},
        "quick": quick,
    }
    save_result("BENCH_sparse", payload)
    return payload


def memory_ratio(results: dict) -> float:
    """Dense/sparse mixing-state memory ratio at the largest fleet."""
    biggest = max(results.values(), key=lambda r: r["n_agents"])
    return biggest["dense_mixing_state_bytes"] / max(
        1, biggest["sparse_mixing_state_bytes"]
    )


if __name__ == "__main__":
    payload = run()
    for k, r in payload["results"].items():
        print(
            f"{k}: {r['per_round_s'] * 1e3:.2f} ms/round, "
            f"mixing state {r['sparse_mixing_state_bytes']:,} B sparse vs "
            f"{r['dense_mixing_state_bytes']:,} B dense"
        )
    print(f"parity@n={payload['parity']['n']}: ok={payload['parity']['ok']}")
