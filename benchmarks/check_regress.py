"""CI perf-regression gate over the ``artifacts/bench`` baselines.

    PYTHONPATH=src python -m benchmarks.check_regress \
        --baseline artifacts/bench --fresh artifacts/fresh

Compares freshly-produced ``BENCH_*.json`` artifacts against the committed
baselines using the per-metric tolerances in :mod:`repro.obs.regress`
(deterministic metrics tight, wall-clock loose) and exits 1 on any
regression.  Stdlib-only: the CI lane needs no jax/numpy install.

Intentional perf changes update the baselines in-place:

    PYTHONPATH=src python -m benchmarks.check_regress \
        --baseline artifacts/bench --fresh artifacts/fresh --update-baselines

then commit the rewritten ``artifacts/bench/*.json`` with the PR that
changed the numbers.
"""
from __future__ import annotations

import argparse
import glob
import os
import shutil
import sys

from repro.obs.regress import compare_dirs, format_findings


def update_baselines(baseline_dir: str, fresh_dir: str) -> int:
    """Copy every fresh BENCH_*.json (+ MANIFEST.json) over the baselines."""
    os.makedirs(baseline_dir, exist_ok=True)
    copied = 0
    patterns = ("BENCH_*.json", "MANIFEST.json")
    for pat in patterns:
        for src in sorted(glob.glob(os.path.join(fresh_dir, pat))):
            dst = os.path.join(baseline_dir, os.path.basename(src))
            shutil.copyfile(src, dst)
            print(f"updated {dst}")
            copied += 1
    return copied


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--baseline", default="artifacts/bench",
        help="directory of committed baseline artifacts",
    )
    ap.add_argument(
        "--fresh", required=True,
        help="directory of freshly-produced artifacts to gate",
    )
    ap.add_argument(
        "--only", nargs="*", default=None,
        help="restrict to these bench keys (e.g. driver async)",
    )
    ap.add_argument(
        "--update-baselines", action="store_true",
        help="copy fresh artifacts over the baselines instead of gating "
             "(for intentional perf changes; commit the result)",
    )
    args = ap.parse_args(argv)

    if args.update_baselines:
        n = update_baselines(args.baseline, args.fresh)
        if n == 0:
            print(f"no BENCH_*.json found under {args.fresh}", file=sys.stderr)
            return 1
        return 0

    findings = compare_dirs(args.baseline, args.fresh, only=args.only)
    print(format_findings(findings))
    if not any(f.status != "skipped" for f in findings):
        # nothing was actually compared (empty fresh dir, bad --only, all
        # benches missing from one side) — that's a broken gate, not a pass
        print(
            f"no metrics compared (baseline={args.baseline} "
            f"fresh={args.fresh})", file=sys.stderr,
        )
        return 1
    if any(f.failed for f in findings):
        print("perf regression detected — see table above. "
              "If intentional, re-baseline with --update-baselines.",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
