"""End-to-end driver: PISCO-train a ~126M-parameter decoder LM for a few
hundred communication rounds on heterogeneous token streams.

This is the deliverable (b) end-to-end example: real model (GQA + SwiGLU,
12 layers, d_model 768, vocab 8192 ~ 126M params), real data pipeline
(per-agent Zipf streams with distinct bigram structure = heterogeneity),
PISCO rounds with a Bernoulli(p) server schedule, checkpointing, and eval.

    PYTHONPATH=src python examples/train_federated_lm.py --rounds 300

On the CPU container a round takes O(10 s); pass --rounds 20 for a smoke run.
The same ModelBundle/step functions drive the production mesh via
repro.launch.{train,dryrun}.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.core import PiscoConfig, dense_mixing, make_topology, replicate_params
from repro.core.algorithms import get_algorithm
from repro.core.driver import make_block_fn, predraw_schedule, sample_block
from repro.core.schedule import CommAccountant
from repro.data.synthetic import synthetic_lm_tokens
from repro.models import ModelConfig, config_to_dict, get_bundle

LM_100M = ModelConfig(
    name="pisco-lm-100m",
    arch_type="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=4,
    head_dim=64,
    d_ff=3072,
    vocab_size=8192,
    mlp_type="swiglu",
    dtype="float32",
    attn_chunk=256,
    remat=False,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=300)
    ap.add_argument("--n-agents", type=int, default=4)
    ap.add_argument("--t-o", type=int, default=1)
    ap.add_argument("--p", type=float, default=0.1)
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--eta-l", type=float, default=0.1)
    ap.add_argument("--local-opt", default=None,
                    help="local update rule, e.g. momentum | adam:lr=0.01 "
                         "(default: hardcoded tracked-SGD)")
    ap.add_argument("--server-opt", default=None,
                    help="FedOpt server rule, e.g. fedavgm | fedadam")
    ap.add_argument("--lr-schedule", default=None,
                    help="local-LR decay: linear | cosine | warmup_cosine")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args()

    cfg = LM_100M
    bundle = get_bundle(cfg)
    n_params = cfg.param_count()
    print(f"model={cfg.name}: {n_params/1e6:.0f}M params, "
          f"{args.n_agents} agents, T_o={args.t_o}, p={args.p}")

    # heterogeneous per-agent streams (different bigram structure per agent)
    streams = [
        synthetic_lm_tokens(500_000, cfg.vocab_size, seed=31 * a + 1)
        for a in range(args.n_agents)
    ]
    rng = np.random.default_rng(0)

    def sample_round(_k):
        def one_set():
            out = []
            for a in range(args.n_agents):
                s = streams[a]
                starts = rng.integers(0, len(s) - args.seq - 1, size=args.batch)
                out.append(np.stack([s[i : i + args.seq] for i in starts]))
            return np.stack(out)

        sets = np.stack([one_set() for _ in range(args.t_o + 1)])
        local = {"tokens": jnp.asarray(sets[: args.t_o])}
        comm = {"tokens": jnp.asarray(sets[-1])}
        return local, comm

    pcfg = PiscoConfig(
        n_agents=args.n_agents, t_o=args.t_o, eta_l=args.eta_l, eta_c=1.0, p=args.p
    )
    topo = make_topology("ring", args.n_agents)
    mixing = dense_mixing(topo)
    # Registry API: one bound algorithm (round fns + Bernoulli(p) schedule +
    # comm profile), one jitted scan over each block of rounds.  The same
    # UpdateRule API that drives the logreg experiments plugs in here — e.g.
    # `--local-opt momentum --server-opt fedadam` is PISCO-M with FedAdam
    # server rounds on a 126M-param LM.
    from repro.optim import resolve_update_rules

    opt_kw = resolve_update_rules(
        args.local_opt, args.server_opt, args.lr_schedule,
        eta_l=args.eta_l, rounds=args.rounds, t_o=args.t_o,
    )
    bound = get_algorithm("pisco").bind(bundle.loss, pcfg, mixing, **opt_kw)
    block_fn = make_block_fn(bound)
    acct = CommAccountant()

    params = bundle.init(jax.random.PRNGKey(0))
    x0 = replicate_params(params, args.n_agents)
    local0, comm0 = sample_round(-1)
    state = bound.init(bundle.loss, x0, comm0)

    losses = []
    t0 = time.perf_counter()
    k = 0
    while k < args.rounds:
        # blocks end at log points and checkpoint multiples
        stop = min(k + args.log_every, args.rounds)
        if args.ckpt_dir:
            stop = min(stop, ((k // 100) + 1) * 100)
        flags = predraw_schedule(bound.schedule, k, stop)
        local, comm = sample_block(sample_round, k, stop)
        state, metrics = block_fn(state, jnp.asarray(flags), local, comm)
        for f in flags:
            acct.record(bool(f))
        losses.extend(np.asarray(metrics.loss, dtype=np.float64).tolist())
        dt = time.perf_counter() - t0
        print(
            f"round {stop - 1:4d} [{'J' if flags[-1] else 'W'}] loss={losses[-1]:.4f} "
            f"consensus={float(metrics.consensus_err[-1]):.2e} ({dt/stop:.1f}s/round)"
        )
        if args.ckpt_dir and stop % 100 == 0:
            save_checkpoint(
                args.ckpt_dir, stop, state,
                metadata={"model": config_to_dict(cfg)},
            )
        k = stop

    if args.ckpt_dir:
        # final-state checkpoint regardless of round count; the manifest
        # carries the model config, so the serving launcher rebuilds the
        # bundle from the checkpoint alone
        path = save_checkpoint(
            args.ckpt_dir, args.rounds, state,
            metadata={"model": config_to_dict(cfg)},
        )
        print(f"saved final checkpoint: {path}")
        print(
            "serve it:  PYTHONPATH=src python -m repro.launch.serve "
            f"--ckpt {path} --delta topk:f=0.05"
        )
    print(
        f"\nfinal: loss {losses[0]:.4f} -> {losses[-1]:.4f} over {args.rounds} rounds "
        f"({acct.agent_to_agent} gossip / {acct.agent_to_server} server)"
    )
    assert losses[-1] < losses[0], "training must reduce loss"


if __name__ == "__main__":
    main()
