"""The paper's Fig. 7 workload as a runnable example: CNN on a sorted
(CIFAR-like) split over a 5-agent ring, comparing p in {0, 0.2, 1}.

    PYTHONPATH=src python examples/semi_decentralized_cnn.py --rounds 40
"""
import argparse

import jax
import jax.numpy as jnp

from repro.core import PiscoConfig, dense_mixing, make_topology, replicate_params, run_training
from repro.data import FederatedDataset, RoundSampler
from repro.data.synthetic import synthetic_cifar
from repro.models.simple import cnn_accuracy, cnn_init, cnn_loss


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--t-o", type=int, default=4)
    args = ap.parse_args()

    x, y = synthetic_cifar(3000, seed=0)
    data = FederatedDataset.from_arrays(x, y, 5, heterogeneous=True)
    topo = make_topology("ring", 5)
    mixing = dense_mixing(topo)
    xe, ye = jnp.asarray(data.x_test), jnp.asarray(data.y_test)

    def eval_fn(params):
        return {"test_acc": float(cnn_accuracy(params, xe, ye))}

    print(f"5-agent ring (lambda_w={topo.lambda_w:.3f}), sorted CIFAR-like split, "
          f"T_o={args.t_o}")
    for p in (0.0, 0.2, 1.0):
        cfg = PiscoConfig(n_agents=5, t_o=args.t_o, eta_l=0.05, eta_c=1.0, p=p, seed=0)
        sampler = RoundSampler(data, batch_size=20, t_o=args.t_o, seed=0)
        x0 = replicate_params(cnn_init(jax.random.PRNGKey(0)), 5)
        hist = run_training(
            "pisco", cnn_loss, x0, cfg, mixing, sampler,
            rounds=args.rounds, eval_fn=eval_fn, eval_every=max(1, args.rounds // 8),
        )
        print(
            f"  p={p:4.1f}: loss {hist.loss[0]:.3f} -> {hist.loss[-1]:.3f}, "
            f"test acc {hist.eval_metrics[-1]['test_acc']:.3f} "
            f"({hist.accountant.agent_to_server} server rounds)"
        )


if __name__ == "__main__":
    main()
