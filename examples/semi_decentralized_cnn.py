"""The paper's Fig. 7 workload as a runnable example: CNN on a sorted
(CIFAR-like) split over a 5-agent ring, comparing p in {0, 0.2, 1} with one
declarative grid sweep over the ExperimentSpec API.

    PYTHONPATH=src python examples/semi_decentralized_cnn.py --rounds 40
"""
import argparse

import jax
import jax.numpy as jnp

from repro.core import Experiment, ExperimentSpec
from repro.data import FederatedDataset, RoundSampler
from repro.data.synthetic import synthetic_cifar
from repro.models.simple import cnn_accuracy, cnn_init, cnn_loss


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--t-o", type=int, default=4)
    args = ap.parse_args()

    x, y = synthetic_cifar(3000, seed=0)
    data = FederatedDataset.from_arrays(x, y, 5, heterogeneous=True)
    xe, ye = jnp.asarray(data.x_test), jnp.asarray(data.y_test)

    def eval_fn(params):
        return {"test_acc": float(cnn_accuracy(params, xe, ye))}

    spec = ExperimentSpec.create(
        algo="pisco", n_agents=5, t_o=args.t_o, eta_l=0.05, eta_c=1.0, p=0.0,
        seed=0, topology="ring", rounds=args.rounds,
        eval_every=max(1, args.rounds // 8), driver="scan",
    )
    exp = Experiment(
        spec,
        loss_fn=cnn_loss,
        params0=cnn_init(jax.random.PRNGKey(0)),
        sampler_factory=lambda s: RoundSampler(
            data, batch_size=20, t_o=s.config.t_o, seed=s.config.seed
        ),
        eval_fn=eval_fn,
    )

    print(f"5-agent ring, sorted CIFAR-like split, T_o={args.t_o}")
    for run_spec, hist in exp.sweep(grid={"p": [0.0, 0.2, 1.0]}):
        print(
            f"  p={run_spec.config.p:4.1f}: loss {hist.loss[0]:.3f} -> {hist.loss[-1]:.3f}, "
            f"test acc {hist.eval_metrics[-1]['test_acc']:.3f} "
            f"({hist.accountant.agent_to_server} server rounds)"
        )


if __name__ == "__main__":
    main()
