"""Quickstart: PISCO in ~60 lines.

Federated nonconvex logistic regression over a ring of 10 agents with a
probabilistic server (p = 0.1), gradient tracking, and T_o = 5 local updates.

    PYTHONPATH=src python examples/quickstart.py
"""
import functools

import jax.numpy as jnp

from repro.core import PiscoConfig, dense_mixing, make_topology, replicate_params, run_training
from repro.data import FederatedDataset, RoundSampler
from repro.data.synthetic import synthetic_a9a
from repro.models.simple import logreg_accuracy, logreg_loss


def main():
    # 1. Federated data: sorted-label split (extreme heterogeneity, paper §5)
    x, y = synthetic_a9a(8000, seed=0)
    data = FederatedDataset.from_arrays(x, y, n_agents=10, heterogeneous=True)

    # 2. Semi-decentralized network: ring gossip + server w.p. p
    topo = make_topology("ring", 10)
    mixing = dense_mixing(topo)
    cfg = PiscoConfig(n_agents=10, t_o=5, eta_l=0.3, eta_c=1.0, p=0.1, seed=0)
    print(f"ring lambda_w={topo.lambda_w:.3f}  expected lambda_p={topo.expected_rate(cfg.p):.3f}")

    # 3. Train
    loss_fn = functools.partial(logreg_loss, rho=0.01)
    sampler = RoundSampler(data, batch_size=128, t_o=cfg.t_o)
    x0 = replicate_params({"w": jnp.zeros(x.shape[1])}, cfg.n_agents)

    x_all = jnp.asarray(data.x_train.reshape(-1, data.x_train.shape[-1]))
    y_all = jnp.asarray(data.y_train.reshape(-1))

    def eval_fn(params):
        # metrics at the agent-average parameters x-bar (the paper's readout)
        acc = logreg_accuracy(params, jnp.asarray(data.x_test), jnp.asarray(data.y_test))
        gl = loss_fn(params, (x_all, y_all))
        return {"test_acc": float(acc), "global_loss": float(gl)}

    hist = run_training(
        "pisco", loss_fn, x0, cfg, mixing, sampler,
        rounds=100, eval_fn=eval_fn, eval_every=10,
    )

    # 4. Report
    print(
        f"global loss at x-bar: {hist.eval_metrics[0]['global_loss']:.4f} -> "
        f"{hist.eval_metrics[-1]['global_loss']:.4f}"
    )
    print(f"test accuracy: {hist.eval_metrics[-1]['test_acc']:.3f}")
    print(
        f"communication: {hist.accountant.agent_to_agent} cheap gossip rounds, "
        f"{hist.accountant.agent_to_server} server rounds"
    )


if __name__ == "__main__":
    main()
