"""Quickstart: PISCO through the ExperimentSpec API in ~50 lines.

Federated nonconvex logistic regression over a ring of 10 agents with a
probabilistic server (p = 0.1), gradient tracking, and T_o = 5 local updates.
The spec is declarative (dict/JSON round-trippable); the run executes through
the chunked on-device scan driver.

    PYTHONPATH=src python examples/quickstart.py
"""
import functools

import jax.numpy as jnp

from repro.core import Experiment, ExperimentSpec
from repro.data import FederatedDataset, RoundSampler
from repro.data.synthetic import synthetic_a9a
from repro.models.simple import logreg_accuracy, logreg_loss


def main():
    # 1. Federated data: sorted-label split (extreme heterogeneity, paper §5)
    x, y = synthetic_a9a(8000, seed=0)
    data = FederatedDataset.from_arrays(x, y, n_agents=10, heterogeneous=True)

    # 2. One declarative spec: algorithm (any registry entry), topology,
    #    PiscoConfig, round budget, eval policy, driver.
    spec = ExperimentSpec.create(
        algo="pisco", n_agents=10, t_o=5, eta_l=0.3, eta_c=1.0, p=0.1, seed=0,
        topology="ring", rounds=100, eval_every=10, driver="scan",
    )
    print("spec:", spec.to_json())

    # 3. Bind the problem pieces and run
    loss_fn = functools.partial(logreg_loss, rho=0.01)
    x_all = jnp.asarray(data.x_train.reshape(-1, data.x_train.shape[-1]))
    y_all = jnp.asarray(data.y_train.reshape(-1))

    def eval_fn(params):
        # metrics at the agent-average parameters x-bar (the paper's readout)
        acc = logreg_accuracy(params, jnp.asarray(data.x_test), jnp.asarray(data.y_test))
        gl = loss_fn(params, (x_all, y_all))
        return {"test_acc": float(acc), "global_loss": float(gl)}

    exp = Experiment(
        spec,
        loss_fn=loss_fn,
        params0={"w": jnp.zeros(x.shape[1])},
        sampler_factory=lambda s: RoundSampler(
            data, batch_size=128, t_o=s.config.t_o, seed=s.config.seed
        ),
        eval_fn=eval_fn,
    )
    hist = exp.run()

    # 4. Report
    print(
        f"global loss at x-bar: {hist.eval_metrics[0]['global_loss']:.4f} -> "
        f"{hist.eval_metrics[-1]['global_loss']:.4f}"
    )
    print(f"test accuracy: {hist.eval_metrics[-1]['test_acc']:.3f}")
    print(
        f"communication: {hist.accountant.agent_to_agent} cheap gossip rounds, "
        f"{hist.accountant.agent_to_server} server rounds"
    )

    # 5. Multi-seed confidence, vmapped on-device: every seed advances through
    #    one scanned program.
    hists = exp.sweep(seeds=[0, 1, 2])
    accs = [h.eval_metrics[-1]["test_acc"] for h in hists]
    print(f"3-seed test acc: {min(accs):.3f} .. {max(accs):.3f}")

    # 6. Pluggable update rules (DESIGN.md §10): FedAdam-over-gossip — local
    #    momentum on the tracker, server-side Adam firing at the Bernoulli(p)
    #    global-averaging rounds.  Same spec, two more declarative fields.
    fed_spec = spec.replace(
        optimizer="momentum:lr=0.1", server_optimizer="fedadam"
    )
    fed_hist = Experiment(
        fed_spec,
        loss_fn=loss_fn,
        params0={"w": jnp.zeros(x.shape[1])},
        sampler_factory=lambda s: RoundSampler(
            data, batch_size=128, t_o=s.config.t_o, seed=s.config.seed
        ),
        eval_fn=eval_fn,
    ).run()
    print(
        f"FedAdam-over-gossip: global loss "
        f"{fed_hist.eval_metrics[0]['global_loss']:.4f} -> "
        f"{fed_hist.eval_metrics[-1]['global_loss']:.4f} "
        f"(acc {fed_hist.eval_metrics[-1]['test_acc']:.3f})"
    )


if __name__ == "__main__":
    main()
