"""Continuous-batched serving example: a synthetic personalized fleet served
as base + per-agent deltas, with a per-request latency breakdown.

Each request belongs to a different agent of the fleet; one jitted decode
step advances every occupied slot under that slot's own delta.  The table at
the end splits each request's latency into queue wait (arrival -> admission),
prefill, and decode time.

    PYTHONPATH=src python examples/serve_decode.py --agents 16 --requests 8
"""
import argparse

import jax

from repro.configs import get_reduced
from repro.models import get_bundle
from repro.serve import (
    ArrivalProcess,
    ContinuousBatcher,
    DecodeEngine,
    FleetDelta,
    make_requests,
    run_load,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--agents", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--arrival", default="poisson:rate=4")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    bundle = get_bundle(cfg)
    base = bundle.init(jax.random.PRNGKey(args.seed))
    fleet = FleetDelta.synthetic(base, args.agents, seed=args.seed)
    print(
        f"arch={cfg.name} fleet={fleet.n_agents} agents "
        f"({fleet.spec.name}): {fleet.nbytes()/2**20:.2f} MiB vs "
        f"{fleet.naive_nbytes()/2**20:.2f} MiB naive "
        f"({fleet.naive_nbytes()/max(fleet.nbytes(),1):.1f}x smaller)"
    )

    engine = DecodeEngine(
        bundle, fleet, n_slots=args.slots,
        max_seq=args.prompt_len + args.gen + 8,
    )
    batcher = ContinuousBatcher(engine, seed=args.seed)
    requests = make_requests(
        ArrivalProcess.parse(args.arrival), args.requests,
        n_agents=fleet.n_agents, vocab_size=cfg.vocab_size,
        prompt_len=args.prompt_len, max_new_tokens=args.gen, seed=args.seed,
    )
    report = run_load(batcher, requests)  # measured engine time

    print(
        f"served {len(report.requests)} requests / {report.total_tokens} "
        f"tokens: {report.tokens_per_s:.1f} tok/s, "
        f"p50={report.p50_s*1e3:.0f} ms p99={report.p99_s*1e3:.0f} ms"
    )
    print(
        f"{'req':>4} {'agent':>5} {'tok':>4} {'queue_ms':>9} "
        f"{'prefill_ms':>11} {'decode_ms':>10} {'latency_ms':>11}"
    )
    for r in sorted(report.requests, key=lambda r: r.rid):
        b = r.breakdown()
        print(
            f"{b['rid']:>4} {b['agent']:>5} {b['tokens']:>4} "
            f"{b['queue_wait_s']*1e3:>9.1f} {b['prefill_s']*1e3:>11.1f} "
            f"{b['decode_s']*1e3:>10.1f} {b['latency_s']*1e3:>11.1f}"
        )


if __name__ == "__main__":
    main()
