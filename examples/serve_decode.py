"""Batched serving example: prefill + decode on the Mixtral-family reduced
config (MoE top-2 routing + sliding-window attention with a rolling KV cache).

    PYTHONPATH=src python examples/serve_decode.py --batch 4 --gen 24
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.models import get_bundle


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    bundle = get_bundle(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    max_seq = args.prompt_len + args.gen
    print(
        f"arch={cfg.name} window={cfg.sliding_window} "
        f"experts={cfg.moe.n_experts if cfg.moe else 0} cache_len="
        f"{min(max_seq, cfg.sliding_window) if cfg.sliding_window else max_seq}"
    )

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=(args.batch, args.prompt_len)), jnp.int32
    )
    cache = bundle.init_cache(args.batch, max_seq)

    prefill = jax.jit(bundle.prefill)
    decode = jax.jit(bundle.decode)

    t0 = time.perf_counter()
    logits, cache = prefill(params, {"tokens": prompts}, cache)
    logits.block_until_ready()
    t_pre = time.perf_counter() - t0
    print(f"prefill {args.batch}x{args.prompt_len}: {t_pre*1e3:.0f} ms "
          f"({args.batch*args.prompt_len/t_pre:.0f} tok/s)")

    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    generated = [np.asarray(tok)[:, 0]]
    t1 = time.perf_counter()
    for _ in range(args.gen - 1):
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        generated.append(np.asarray(tok)[:, 0])
    jax.block_until_ready(tok)
    t_dec = time.perf_counter() - t1
    print(f"decode {args.gen-1} steps: {t_dec/(args.gen-1)*1e3:.1f} ms/step "
          f"({args.batch*(args.gen-1)/t_dec:.0f} tok/s)")
    gen = np.stack(generated, axis=1)
    for b in range(min(2, args.batch)):
        print(f"  seq{b}: {gen[b].tolist()}")


if __name__ == "__main__":
    main()
